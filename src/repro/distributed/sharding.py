"""Sharding rules: map every parameter / batch / serving-state tensor to a
PartitionSpec for the production mesh.

Strategy (DESIGN.md §5):
  * TP over ``model``: attention heads, MLP hidden, vocab, MoE experts
    (true EP when num_experts divides |model|, otherwise expert-ff TP).
  * FSDP over ``data`` (+``pod``): the contracting/input dim of each large
    matrix is additionally sharded over the data axes — GSPMD all-gathers one
    layer at a time inside the layer scan (overlappable), and gradients
    reduce-scatter back.  Optimizer state inherits param sharding (ZeRO-1).
  * Batch over (``pod``, ``data``).
  * Serving: lanes over data axes, paged KV pool pages over data axes,
    attention heads over ``model``; the SpeedMalloc allocator metadata
    (int32 free lists / block tables) is tiny and *replicated* — every shard
    runs the same deterministic support-core step, which is the TPU analogue
    of "one owner, zero synchronization" (no collective ever touches it).

Divisibility-aware: any rule that does not divide evenly degrades to
replication for that dim (never fails to compile).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return axes is not None and dim % _axis_size(mesh, axes) == 0


def _spec(mesh: Mesh, shape: tuple[int, ...], wants: list[Any]) -> P:
    """Build a PartitionSpec, dropping axes that don't divide."""
    out = []
    for dim, want in zip(shape, wants):
        out.append(want if _fits(dim, mesh, want) else None)
    return P(*out)


def dp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if axes else None


# --------------------------------------------------------------------------
# Parameter sharding
# --------------------------------------------------------------------------

def param_specs(cfg: ArchConfig, mesh: Mesh, params_tree) -> Any:
    """PartitionSpec tree matching ``params_tree`` (works on abstract trees)."""
    dp = dp_axes(mesh)

    def rule(path: tuple, leaf) -> P:
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = names[-1] if names else ""
        shape = leaf.shape
        nd = len(shape)
        stacked = "layers" in names or "enc_layers" in names or "cross_layers" in names
        off = 1 if stacked else 0   # leading L dim of scanned stacks: replicate

        def w(*wants):
            return _spec(mesh, shape, [None] * off + list(wants))

        if name == "embed":
            return _spec(mesh, shape, ["model", dp])
        if name == "unembed":
            return _spec(mesh, shape, [dp, "model"])
        if name in ("wq", "wk", "wv", "wg", "decay_lora_a"):
            return w(dp, "model") if nd - off == 2 else w("model")
        if name in ("bq", "bk", "bv"):
            return w("model")
        if name in ("wo", "decay_lora_b"):
            return w("model", dp)
        if name == "w_in":
            if nd - off == 3:   # MoE [E, d, ff*]
                if _fits(shape[off], mesh, "model"):
                    return w("model", dp, None)       # EP
                return w(None, dp, "model")           # TP-MoE
            return w(dp, "model")
        if name == "w_out":
            if nd - off == 3:   # MoE [E, ff, d]
                if _fits(shape[off], mesh, "model"):
                    return w("model", None, dp)
                return w(None, "model", dp)
            return w("model", dp)
        if name == "router":
            return w(dp, None)
        if name == "in_proj":    # mamba: mixed-segment projection -> fsdp only
            return w(dp, None)
        if name == "out_proj":
            return w(None, dp)
        if name in ("enc_pos", "dec_pos"):
            return _spec(mesh, shape, [None, dp])
        # norms, biases, conv weights, decay bases, mixing params: replicate
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, params_tree)


def batch_specs(cfg: ArchConfig, mesh: Mesh, batch_tree) -> Any:
    dp = dp_axes(mesh)

    def rule(path, leaf):
        nd = len(leaf.shape)
        return _spec(mesh, leaf.shape, [dp] + [None] * (nd - 1))

    return jax.tree_util.tree_map_with_path(rule, batch_tree)


# --------------------------------------------------------------------------
# Serving-state sharding
# --------------------------------------------------------------------------

def serve_state_specs(cfg: ArchConfig, mesh: Mesh, state_tree) -> Any:
    """Lanes & pages over data axes; KV heads over model when divisible;
    allocator metadata replicated (support-core principle)."""
    dp = dp_axes(mesh)

    def rule(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        name = names[-1] if names else ""
        shape = leaf.shape
        if name in ("k_pages", "v_pages"):
            # [num_pages, L, ps, kv_heads, head_dim]
            from ..perf_flags import current_flags
            layout = current_flags().pool_layout
            if layout == "pages_hd":
                # pages over dp only; head_dim over model: scatter mask
                # groups shrink to |dp| and no sharded-layer dynamic slicing
                return _spec(mesh, shape, [dp, None, None, None, "model"])
            if layout == "layers" \
                    and _fits(shape[1], mesh, dp):
                # layer dim over dp + head_dim (or kv heads) over model: the
                # decode append scatter's indexed dims (pages, ps) are then
                # unsharded -> fully local scatter, no pool-sized collectives
                if _fits(shape[3], mesh, "model"):
                    return _spec(mesh, shape, [None, dp, None, "model", None])
                return _spec(mesh, shape, [None, dp, None, None, "model"])
            # baseline: pages over dp; KV heads over model when divisible,
            # otherwise pages take model too.
            if _fits(shape[3], mesh, "model"):
                return _spec(mesh, shape, [dp, None, None, "model", None])
            pages_axes = tuple(dp) + ("model",) if dp else "model"
            return _spec(mesh, shape, [pages_axes, None, None, None, None])
        if name in ("block_tables", "seq_lens", "active", "state_slot"):
            return P(*([None] * len(shape)))   # metadata: replicated, tiny
        if name in ("free_stack", "free_top", "owner", "capacity", "alloc_count",
                    "free_count", "fail_count", "used", "peak_used"):
            return P(*([None] * len(shape)))   # support-core metadata
        if name == "ssm":      # [L, B, h, dk, dv]
            return _spec(mesh, shape, [None, dp, "model", None, None])
        if name == "conv":     # [L, B, K-1, conv_dim]
            return _spec(mesh, shape, [None, dp, None, None])
        if name in ("tm_prev", "cm_prev"):
            return _spec(mesh, shape, [None, dp, None, None])
        if name == "lane_state":
            return P(*([None] * len(shape)))
        if name == "enc_out":  # [B, F, d]
            return _spec(mesh, shape, [dp, None, None])
        if name == "tokens":
            return _spec(mesh, shape, [dp])
        # scalars / counters
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, state_tree)


def to_shardings(mesh: Mesh, spec_tree) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that degrades gracefully off-mesh."""
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except Exception:
        return x
