"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (data, model) single-pod or 2x16x16 (pod, data, model) multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(model_parallelism: int = 16):
    """Derive a mesh from however many devices are currently alive.

    Elastic-scaling support: after losing a pod/host, re-derive (data, model)
    from the surviving device count; checkpoint restore reshards onto it
    (see repro.distributed.checkpoint).
    """
    n = jax.device_count()
    model = min(model_parallelism, n)
    while n % model:
        model //= 2
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_smoke_mesh():
    """1x1 mesh on the single CPU device (smoke tests of sharded code paths)."""
    n = jax.device_count()
    return jax.make_mesh((n, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The axes a data-parallel batch shards over (includes 'pod' if present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
