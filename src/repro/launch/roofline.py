"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh, derive the three terms:

  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory_s     = HLO_bytes_per_device / HBM_BW
  collective_s = collective_wire_bytes_per_device / ICI_BW

HLO terms come from the scan-corrected extrapolation (XLA's HloCostAnalysis
counts while-loop bodies once — verified on this backend; dryrun.py compiles
two small-unrolled variants and extrapolates linearly in depth).  Collective
bytes use the ring-model wire estimates parsed from the partitioned HLO.

MODEL_FLOPS = 6·N·D for train (N = params, MoE: active), 2·N·D for
inference shapes (forward only), plus attention-specific terms; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment-specified).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from ..configs.base import ARCH_IDS, SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS_SINGLE_POD = 256

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful-FLOPs for the whole step (GLOBAL, all chips)."""
    cfg = get_config(arch)
    shp = SHAPES[shape_name]
    B, S = shp["global_batch"], shp["seq_len"]
    N = cfg.active_param_count()
    hd = cfg.resolved_head_dim

    def attn_flops(tokens, kv_len_avg):
        """QK^T + PV matmul flops for all attention layer instances."""
        n_attn = cfg.num_attn_layers
        if n_attn == 0:
            return 0.0
        return 4.0 * tokens * kv_len_avg * cfg.num_heads * hd * n_attn

    if shp["kind"] == "train":
        D = B * S
        base = 6.0 * N * D
        attn = 3.0 * attn_flops(D, S / 2)     # fwd + 2x bwd
        if cfg.encoder_layers:
            base += 6.0 * 0.0                  # encoder params included in N
            attn += 3.0 * attn_flops(B * cfg.encoder_seq_len, cfg.encoder_seq_len)
        return base + attn
    if shp["kind"] == "prefill":
        D = B * S
        return 2.0 * N * D + attn_flops(D, S / 2)
    # decode: one token per lane against seq_len KV
    D = B
    kv_len = min(S, cfg.window) if cfg.window else S
    return 2.0 * N * D + attn_flops(D, kv_len)


def load_cell(arch: str, shape: str, mesh: str = "pod16x16") -> dict | None:
    p = RESULTS_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def roofline_row(arch: str, shape: str) -> dict:
    rec = load_cell(arch, shape)
    row = {"arch": arch, "shape": shape}
    if rec is None:
        row["status"] = "missing"
        return row
    row["status"] = rec["status"]
    if rec["status"] == "skipped":
        row["reason"] = rec.get("reason", "")
        return row
    if rec["status"] != "ok":
        row["reason"] = rec.get("error", "")[:120]
        return row

    ext = rec.get("extrapolated") or {}
    scn = rec["scanned"]
    flops_dev = max(ext.get("flops", scn["flops"]), scn["flops"])
    bytes_dev = max(ext.get("bytes_accessed", 0.0), scn["bytes_accessed"])
    wire_dev = max(ext.get("collective_wire_total", 0.0),
                   scn.get("collective_wire_total", 0.0))

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = terms[dominant]

    mf = model_flops(arch, shape)
    mf_dev = mf / CHIPS_SINGLE_POD
    useful_ratio = mf_dev / flops_dev if flops_dev else 0.0
    # roofline fraction: useful work at peak / time implied by dominant term
    roofline_frac = (mf_dev / PEAK_FLOPS) / bound_s if bound_s else 0.0

    mem = scn["memory"]
    row.update({
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_dev": flops_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": roofline_frac,
        "hbm_gb_per_dev": (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9,
        "fits_16gb": (mem["argument_bytes"] + mem["temp_bytes"]) / 1e9 <= 16.0,
        "compile_s": rec.get("compile_s"),
    })
    return row


def full_table() -> list[dict]:
    return [roofline_row(a, s) for a in ARCH_IDS for s in SHAPES]


def advice(row: dict) -> str:
    """One sentence on what would move the dominant term down."""
    if row.get("status") != "ok":
        return ""
    d = row["dominant"]
    if d == "collective":
        return ("reduce cross-device traffic: fewer FSDP re-gathers "
                "(larger microbatch / weight-stationary), shard-local paged "
                "pools, or reduce-scatter instead of all-reduce")
    if d == "memory":
        return ("cut HBM traffic: fuse gather+attention (paged kernel), "
                "keep f32 temporaries out of the residual path, larger "
                "attention chunks")
    return ("raise MXU utilization: bigger per-device tiles (less TP), "
            "reduce remat recompute, batch small matmuls")


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful/HLO | roofline frac | HBM GB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skipped | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ? | ? | ? | "
                         f"{r.get('status')} | ? | ? | ? | ? |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['hbm_gb_per_dev']:.1f} | "
            f"{'y' if r['fits_16gb'] else 'N'} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    rows = full_table()
    if args.json:
        print(json.dumps(rows, indent=1))
        return
    print(markdown_table(rows))
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["collective_s"] / max(r["compute_s"], 1e-9))
        print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound: {coll['arch']} x {coll['shape']} "
              f"(coll/comp = {coll['collective_s'] / max(coll['compute_s'], 1e-9):.1f}x)")
        for r in ok:
            if r["dominant"] != "compute":
                print(f"  {r['arch']} x {r['shape']}: {r['dominant']}-bound -> {advice(r)}")


if __name__ == "__main__":
    main()
