"""Trace replay launcher: ``python -m repro.launch.replay TRACE [...]``.

Drives a recorded allocator-op tracefile (``launch.serve --loadgen ...
--record-trace FILE``, or ``repro.loadgen.trace.save_trace``) through the
model-free ``AllocService`` harness — no model forward, so million-request
sweeps over policies/backends run in seconds — and optionally through the
sim's pluggable policies (``--sim``), the ZODB "one tracefile, many
simulators" idiom (DESIGN.md §14).
"""
from __future__ import annotations

import argparse

from ..alloc import ALLOC_POLICIES
from ..core.support_core import ALLOC_BACKENDS
from ..loadgen.trace import load_trace, replay_sim_policies, replay_trace


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="tracefile written by save_trace / "
                                  "--record-trace")
    ap.add_argument("--policy", default=None, choices=list(ALLOC_POLICIES),
                    help="override the recorded allocator policy "
                         "(what-if sweep)")
    ap.add_argument("--backend", default=None, choices=list(ALLOC_BACKENDS),
                    help="override the recorded backend")
    ap.add_argument("--sim", default=None, metavar="POLICIES",
                    help="ALSO replay through comma-separated sim policies "
                         "(e.g. 'speedmalloc,tcmalloc,mimalloc')")
    ap.add_argument("--threads", type=int, default=8,
                    help="sim thread count for --sim lowering")
    args = ap.parse_args()

    trace = load_trace(args.trace)
    h = trace.header
    print(f"{args.trace}: v{h['version']} policy={h['policy']} "
          f"backend={h['backend']} tenants={len(h['tenants'])} "
          f"bursts={trace.bursts} ({trace.live_bursts} live, "
          f"{trace.ops} ops) windows={trace.windows} "
          f"complete={h['complete']}")
    res = replay_trace(trace, policy=args.policy, backend=args.backend)
    print(f"replayed {res.bursts} bursts ({res.live_bursts} live) in "
          f"{res.wall_s:.2f}s ({res.signatures} compiled signature(s)) "
          f"policy={args.policy or h['policy']} "
          f"backend={args.backend or h['backend']}")
    for name, rep in res.report.items():
        print(f"  {name}: used={rep['used']}/{rep['quota']} "
              f"peak={rep['peak_used']} allocs={rep['alloc_count']} "
              f"frees={rep['free_count']} fails={rep['fail_count']}")
    if args.sim:
        rows = replay_sim_policies(trace, policies=args.sim.split(","),
                                   threads=args.threads)
        print(f"sim-policy sweep ({args.threads} threads):")
        for name, r in rows.items():
            print(f"  {name}: mallocs={r['mallocs']} frees={r['frees']} "
                  f"fast_hits={r['fast_hits']} "
                  f"shared_trips={r['shared_trips']} "
                  f"est_cycles={r['est_cycles']:.0f}")


if __name__ == "__main__":
    main()
