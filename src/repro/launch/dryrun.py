import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh) combination lowers,
partitions, and compiles on the production meshes, and extract the roofline
raw terms from the compiled artifacts.

MUST be run as its own process (`python -m repro.launch.dryrun ...`): the two
lines above run before ANY other import (jax locks device count on first
init).  Never import this module from tests/benches.

Per cell this produces (cached under results/dryrun/):
  * scanned step, single-pod 16x16 — memory_analysis (fits?), compile proof
  * scanned step, multi-pod 2x16x16 — proves the "pod" axis shards
  * two small-unrolled variants (L1, L2 layers) — XLA cost extrapolation:
      per_layer = (cost(L2) - cost(L1)) / (L2 - L1)
      total     = cost(L1) - L1*per_layer + num_layers*per_layer
    (needed because XLA's HloCostAnalysis counts a while-loop body ONCE —
    verified empirically on this backend; see EXPERIMENTS.md §Dry-run.)
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, SHAPES, get_config
from ..distributed.hints import ShardingHints
from ..distributed.sharding import (batch_specs, param_specs,
                                    serve_state_specs, to_shardings)
from ..models.model_zoo import (abstract_params, input_specs,
                                make_paged_config)
from ..serve.serve_step import (abstract_serve_state, make_decode_step,
                                make_prefill_step)
from ..train.optimizer import AdamW
from ..train.train_step import make_train_step
from .mesh import make_production_mesh

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

#: grad-accum per arch for train_4k (memory-driven; see EXPERIMENTS.md §Perf)
GRAD_ACCUM = {
    "qwen2-72b": 8, "phi3-medium-14b": 4, "deepseek-7b": 4,
    "mixtral-8x7b": 8, "phi3.5-moe-42b-a6.6b": 8, "rwkv6-7b": 8,
    "phi-3-vision-4.2b": 4, "zamba2-1.2b": 4, "gemma3-1b": 2,
    "whisper-medium": 4,
}

#: decode shapes skipped for pure full-attention archs (DESIGN.md §4)
LONG_SKIP = {
    "deepseek-7b": "pure full attention (O(S) KV at 500k infeasible by design)",
    "phi3-medium-14b": "pure full attention",
    "qwen2-72b": "pure full attention",
    "phi-3-vision-4.2b": "pure full attention backbone",
    "phi3.5-moe-42b-a6.6b": "pure full attention",
    "whisper-medium": "decoder ctx 448 << 500k (enc-dec)",
}

SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")
DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
               "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
               "s8": 1, "u8": 1, "pred": 1}
# `%op.N = <result types> op-name(...), ... replica_groups=...`
COLLECTIVE_LINE_RE = re.compile(
    r"=\s*(.+?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(-start)?\(")
GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_text: str) -> float:
    total = 0.0
    for sm in SHAPE_RE.finditer(type_text):
        dt, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        key = dt if not dt.startswith("f8") else "s8"
        total += n * DTYPE_BYTES.get(key, 4)
    return total


def _group_size(line: str) -> int:
    m = GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def parse_collective_bytes(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per-collective-op byte totals from the per-device optimized HLO.

    Optimized HLO omits operand types, so sizes come from the *result* type
    plus the replica group size:
      operand_bytes — the spec's "sum of operand sizes":
        all-gather: result/G; reduce-scatter: result*G; others: result.
      wire_bytes — ring-estimate of per-device link traffic:
        all-reduce 2*(G-1)/G*N; gather/scatter/all-to-all (G-1)/G*N_big;
        permute N.
    """
    ops: dict[str, dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_LINE_RE.search(line)
        if not m:
            continue
        result_t, op = m.group(1), m.group(2)
        res = _shape_bytes(result_t)
        g = max(_group_size(line), 1)
        if op == "all-gather":
            operand = res / g
            wire = res * (g - 1) / g
        elif op == "reduce-scatter":
            operand = res * g
            wire = operand * (g - 1) / g
        elif op == "all-reduce":
            operand = res
            wire = 2 * res * (g - 1) / g
        elif op == "all-to-all":
            operand = res
            wire = res * (g - 1) / g
        else:  # collective-permute
            operand = res
            wire = res
        d = ops.setdefault(op, {"count": 0, "operand_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += operand
        d["wire_bytes"] += wire
    return ops


def _variant_cfg(cfg, n_layers: int):
    """Reduce layer count, preserving the layer-pattern period."""
    repl = dict(num_layers=n_layers)
    if cfg.encoder_layers:
        repl["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **repl)


def _layer_period(cfg) -> int:
    if cfg.family == "hybrid":
        return max(cfg.attn_every, 1)
    if cfg.attn_pattern == "local_global":
        return cfg.local_per_global + 1
    return 1


def build_lowering(arch: str, shape_name: str, mesh, *, n_layers=None,
                   scanned=True, dtype=jnp.bfloat16):
    """Build and lower one cell's step on the given mesh.

    scanned=False unrolls every layer scan (and disables grad accum) so XLA
    cost analysis sees each layer — used for the cost extrapolation variants.
    """
    cfg = get_config(arch)
    unroll = not scanned
    if n_layers is not None:
        cfg = _variant_cfg(cfg, n_layers)
    shp = SHAPES[shape_name]
    kind = shp["kind"]
    hints = ShardingHints(mesh)
    params_abs = abstract_params(cfg, dtype)
    psh = to_shardings(mesh, param_specs(cfg, mesh, params_abs))

    if kind == "train":
        from jax.sharding import NamedSharding, PartitionSpec
        opt = AdamW()
        opt_abs = opt.abstract_init(params_abs)
        osh = type(opt_abs)(
            step=NamedSharding(mesh, PartitionSpec()),
            m=to_shardings(mesh, param_specs(cfg, mesh, opt_abs.m)),
            v=to_shardings(mesh, param_specs(cfg, mesh, opt_abs.v)))
        batch = input_specs(cfg, shape_name, act_dtype=dtype)
        bsh = to_shardings(mesh, batch_specs(cfg, mesh, batch))
        accum = GRAD_ACCUM.get(arch, 2) if scanned else 1
        accum = int(os.environ.get("REPRO_GRAD_ACCUM", accum))
        step = make_train_step(cfg, opt, grad_accum=accum, remat=True,
                               hints=hints, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh))
        return jitted.lower(params_abs, opt_abs, batch), cfg

    if kind == "prefill":
        batch = input_specs(cfg, shape_name, act_dtype=dtype)
        bsh = to_shardings(mesh, batch_specs(cfg, mesh, batch))
        step = make_prefill_step(cfg, hints=hints, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(psh, bsh))
        return jitted.lower(params_abs, batch), cfg

    # decode
    lanes, seq = shp["global_batch"], shp["seq_len"]
    kvcfg = make_paged_config(cfg, seq_len=seq, lanes=lanes, dtype=dtype)
    state_abs = abstract_serve_state(cfg, kvcfg, lanes, prefilled_len=seq, dtype=dtype)
    ssh = to_shardings(mesh, serve_state_specs(cfg, mesh, state_abs))
    step = make_decode_step(cfg, kvcfg, hints=hints, unroll=unroll)
    jitted = jax.jit(step, in_shardings=(psh, ssh))
    return jitted.lower(params_abs, state_abs), cfg


def analyze_compiled(lowered, compiled) -> dict:
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = parse_collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "collective_bytes_total": float(sum(d["operand_bytes"] for d in coll.values())),
        "collective_wire_total": float(sum(d["wire_bytes"] for d in coll.values())),
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        },
    }


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             cost_extrapolate: bool = True, force: bool = False) -> dict:
    """Dry-run one (arch x shape) on one mesh; returns the result record."""
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    out_path = RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "when": time.strftime("%Y-%m-%d %H:%M:%S")}
    if shape_name == "long_500k" and arch in LONG_SKIP:
        record["status"] = "skipped"
        record["reason"] = LONG_SKIP[arch]
        out_path.write_text(json.dumps(record, indent=2))
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        t0 = time.time()
        lowered, _ = build_lowering(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        record["status"] = "ok"
        record["lower_s"] = round(t1 - t0, 1)
        record["compile_s"] = round(t2 - t1, 1)
        record["scanned"] = analyze_compiled(lowered, compiled)
        print(f"[{arch} | {shape_name} | {mesh_name}] compiled "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s) "
              f"mem={record['scanned']['memory']}", flush=True)
        del compiled, lowered

        if cost_extrapolate and not multi_pod:
            period = _layer_period(cfg)
            l1, l2 = period, 2 * period
            costs = {}
            for nl in (l1, l2):
                lo, vcfg = build_lowering(arch, shape_name, mesh,
                                          n_layers=nl, scanned=False)
                co = lo.compile()
                costs[nl] = analyze_compiled(lo, co)
                del lo, co
            record["unrolled"] = {str(k): v for k, v in costs.items()}
            record["extrapolated"] = extrapolate(cfg, costs, l1, l2)
            print(f"  extrapolated: {record['extrapolated']}", flush=True)
    except Exception as e:  # noqa: BLE001 — record failures as data
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch} | {shape_name} | {mesh_name}] FAILED: {record['error']}",
              flush=True)
    out_path.write_text(json.dumps(record, indent=2))
    return record


def extrapolate(cfg, costs: dict, l1: int, l2: int) -> dict:
    """Linear-in-layers extrapolation of XLA cost terms to the full depth."""
    L = cfg.num_layers
    out = {}
    for key in ("flops", "bytes_accessed", "collective_bytes_total",
                "collective_wire_total"):
        c1, c2 = costs[l1][key], costs[l2][key]
        per_layer = (c2 - c1) / (l2 - l1)
        fixed = c1 - l1 * per_layer
        out[key] = fixed + L * per_layer
        out[key + "_per_layer"] = per_layer
        out[key + "_fixed"] = fixed
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", choices=["all", *SHAPES])
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, multi_pod=mp,
                               cost_extrapolate=not args.no_extrapolate,
                               force=args.force)
                failures += rec.get("status") == "error"
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
