"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Scheduler-driven continuous-batching demo on the SpeedMalloc paged KV cache:
Poisson-ish request arrivals with Pareto-ish lengths (the paper's
Larson-style server-client pattern) flow through the request-lifecycle
scheduler (DESIGN.md §3) — waiting queue -> prefill buckets -> running lanes
-> completion.  Each admission batch costs ONE support-core HMQ burst and at
most one XLA compile per prefill bucket; decode issues one HMQ batch per
step; completion releases lanes through OP_FREE/FREE_ALL packets.  Prints
allocator + scheduler telemetry (live pages, peak, bursts, compiles).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from ..alloc import ALLOC_POLICIES
from ..configs.base import ARCH_IDS, smoke_config
from ..core.paged_kv import live_pages
from ..core.support_core import ALLOC_BACKENDS
from ..models import init_params, make_paged_config
from ..serve.engine import AdmissionItem, ServingEngine
from ..serve.scheduler import Request, Scheduler, make_scheduler_config


def synth_requests(cfg, n: int, rng: np.random.RandomState) -> list[Request]:
    reqs = []
    for rid in range(n):
        plen = int(rng.pareto(2.0) * 20) % 96 + 8
        reqs.append(Request(
            rid=rid,
            tokens=rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32),
            frames=(rng.randn(cfg.encoder_seq_len, cfg.d_model).astype(np.float32)
                    if cfg.family == "audio" else None),
            patches=(rng.randn(4, cfg.d_model).astype(np.float32)
                     if cfg.family == "vlm" else None),
        ))
    return reqs


def serve_loop(eng: ServingEngine, sched: Scheduler,
               requests: list[Request], max_new_tokens: int,
               log_every: int = 8, verbose: bool = True,
               step_times_us: list | None = None) -> int:
    """Drive the scheduler/engine lifecycle until every request completes.

    Returns the number of decode steps taken.  When ``step_times_us`` is
    given, per-decode-step wall times (µs) are appended to it (benchmark
    hook).  If admission starves with nothing running — the pool cannot fit
    any waiting request — the loop stops and reports the stranded requests
    loudly rather than silently undercounting.
    """
    import time

    for req in requests:
        req.max_new_tokens = max_new_tokens
        sched.submit(req)

    step = 0
    while sched.has_work:
        plan = sched.plan_admission(eng.free_pages)
        if plan.size:
            items = [AdmissionItem(lane, r.tokens, r.frames, r.patches)
                     for b in plan.batches for lane, r in b.items]
            failed = eng.admit_many(items)   # failed lanes come back reclaimed
            sched.commit_admission(plan)
            if failed:
                sched.fail_admission(failed)
                print(f"WARNING: allocator rejected admission of "
                      f"{len(failed)} request(s) (pool exhausted)")
        if not sched.running:
            break                      # nothing admissible: pool too small
        t0 = time.perf_counter()
        eng.step()
        if step_times_us is not None:
            step_times_us.append((time.perf_counter() - t0) * 1e6)
        step += 1
        finished = sched.note_decode_step()
        if finished:
            eng.release(finished)
            sched.complete(finished)
        if verbose and step % log_every == 0:
            print(f"step {step}: done={len(sched.finished)}/{len(requests)} "
                  f"waiting={len(sched.waiting)} "
                  f"live_pages={eng.live_pages} "
                  f"peak={int(eng.state.paged.alloc.peak_used[0])}")
    if sched.waiting:
        print(f"WARNING: admission starved — {len(sched.waiting)} request(s) "
              f"not served (page budget {eng.free_pages} free - "
              f"{sched.scfg.page_reserve} reserve cannot fit the next one)")
    return step


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--stash-size", type=int, default=None,
                    help="per-lane page-stash size (0 disables the front "
                         "tier; default: autotuned from boundary cadence)")
    ap.add_argument("--alloc-backend", default=None,
                    choices=list(ALLOC_BACKENDS),
                    help="support-core step implementation (default: "
                         "REPRO_ALLOC_BACKEND env or 'jnp'; 'kernel' is the "
                         "fused Pallas burst, TPU only; 'kernel-interpret' "
                         "runs it through the Pallas interpreter)")
    ap.add_argument("--alloc-policy", default=None,
                    choices=list(ALLOC_POLICIES),
                    help="central-allocator policy (default: "
                         "REPRO_ALLOC_POLICY env or 'freelist'; 'bitmap' is "
                         "the address-ordered first-fit AllocatorPolicy — "
                         "DESIGN.md §9)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    rng = np.random.RandomState(args.seed)
    kvcfg = make_paged_config(cfg, seq_len=256, lanes=args.lanes,
                              page_size=args.page_size, dtype=jnp.float32,
                              stash_size=args.stash_size)
    params = init_params(cfg, dtype=jnp.float32)
    scfg = make_scheduler_config(cfg, kvcfg, max_prompt_len=128)
    eng = ServingEngine(cfg, kvcfg, params, dtype=jnp.float32, sched_cfg=scfg,
                        alloc_backend=args.alloc_backend,
                        alloc_policy=args.alloc_policy)
    sched = Scheduler(scfg)

    requests = synth_requests(cfg, args.requests, rng)
    steps = serve_loop(eng, sched, requests, args.max_new_tokens)

    a = eng.state.paged.alloc
    s = eng.stats
    if sched.failed:
        print(f"FAILED: {len(sched.failed)} request(s) rejected by the allocator")
    print(f"served {len(sched.finished)} requests in {steps} decode steps | "
          f"alloc_backend={eng.alloc_backend} alloc_policy={eng.alloc_policy} "
          f"stash={kvcfg.stash_size}/{kvcfg.stash_watermark}"
          f"/{kvcfg.stash_refill} | "
          f"allocs={int(a.alloc_count[0])} frees={int(a.free_count[0])} "
          f"fails={int(a.fail_count[0])} peak_pages={int(a.peak_used[0])} "
          f"live={int(live_pages(eng.state.paged))} | "
          f"admit_bursts={s.hmq_admit_bursts} "
          f"({s.hmq_admit_bursts / max(s.admitted, 1):.2f}/seq) "
          f"prefill_compiles={s.prefill_compiles} | "
          f"stash_hit_rate={s.stash_hit_rate:.2f} "
          f"decode_bursts/1k={s.hmq_bursts_per_1k_decode_steps:.0f} "
          f"stash_depth_hist={s.stash_depth_hist}")
    # per-tenant view: the multi-tenant support-core claim, measured
    print(f"burst_occupancy={s.burst_occupancy:.2f} | tenants:")
    for name, rep in eng.tenant_report().items():
        acc = s.tenants.get(name, {})
        print(f"  {name}: used={rep['used']}/{rep['quota']} "
              f"peak={rep['peak_used']} allocs={rep['alloc_count']} "
              f"frees={rep['free_count']} fails={rep['fail_count']} "
              f"(burst mallocs={acc.get('mallocs', 0)} "
              f"failed={acc.get('failed', 0)})")


if __name__ == "__main__":
    main()
