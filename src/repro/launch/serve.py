"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Scheduler-driven continuous-batching demo on the SpeedMalloc paged KV cache:
Poisson-ish request arrivals with Pareto-ish lengths (the paper's
Larson-style server-client pattern) flow through the request-lifecycle
scheduler (DESIGN.md §3) — waiting queue -> prefill buckets -> running lanes
-> completion.  Each admission batch costs ONE support-core HMQ burst and at
most one XLA compile per prefill bucket; decode issues one HMQ batch per
step; completion releases lanes through OP_FREE/FREE_ALL packets.  Prints
allocator + scheduler telemetry (live pages, peak, bursts, compiles).

``--engines N`` (N > 1) switches to the multi-engine sharded deployment
(DESIGN.md §10): N engine shards registered as disjoint namespaced tenant
sets on ONE shared AllocService, an async decode loop that merges every
shard's deferrable allocator traffic into one commit per ``--quantum``-step
burst window, and (with ``--preemption``) scheduler eviction of
lowest-priority lanes under pool pressure.

``--loadgen poisson|bursty|diurnal`` replaces the closed-loop drain with
the OPEN-loop driver (DESIGN.md §14): a seeded arrival process with
heavy-tailed lengths submits requests by virtual arrival time regardless
of completion, and the run reports p50/p90/p99 time-to-first-token,
per-token latency, and queue depth instead of just throughput.
``--record-trace FILE`` additionally serializes the allocator-op stream to
a versioned tracefile for model-free replay (``repro.loadgen.trace``).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from ..alloc import ALLOC_POLICIES, EVICTION_POLICIES
from ..configs.base import ARCH_IDS, smoke_config
from ..core.paged_kv import live_pages
from ..core.support_core import ALLOC_BACKENDS
from ..models import init_params, make_paged_config
from ..serve.engine import ServingEngine, run_admission
from ..serve.multi_engine import MultiEngine
from ..serve.router import ROUTER_POLICIES
from ..serve.scheduler import Request, Scheduler, make_scheduler_config


def synth_requests(cfg, n: int, rng: np.random.RandomState,
                   priority_every: int = 0) -> list[Request]:
    """Larson-style synthetic request mix.  ``priority_every=k`` marks every
    k-th request priority 1 (the preemption demo's high-priority tier)."""
    reqs = []
    for rid in range(n):
        plen = int(rng.pareto(2.0) * 20) % 96 + 8
        reqs.append(Request(
            rid=rid,
            tokens=rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32),
            frames=(rng.randn(cfg.encoder_seq_len, cfg.d_model).astype(np.float32)
                    if cfg.family == "audio" else None),
            patches=(rng.randn(4, cfg.d_model).astype(np.float32)
                     if cfg.family == "vlm" else None),
            priority=1 if priority_every and rid and rid % priority_every == 0
            else 0,
        ))
    return reqs


def serve_loop(eng: ServingEngine, sched: Scheduler,
               requests: list[Request], max_new_tokens: int,
               log_every: int = 8, verbose: bool = True,
               step_times_us: list | None = None,
               preemption: bool = False) -> int:
    """Drive the scheduler/engine lifecycle until every request completes.

    Returns the number of decode steps taken.  When ``step_times_us`` is
    given, per-decode-step wall times (µs) are appended to it (benchmark
    hook).  If admission starves with nothing running — the pool cannot fit
    any waiting request — the loop stops and reports the stranded requests
    loudly rather than silently undercounting.  ``preemption`` enables the
    scheduler's priority eviction (DESIGN.md §10): when a waiting request
    outranks a running one and admission is stuck, the lowest-priority
    running lane is FREE_ALLed and its request re-queued with its generated
    prefix.
    """
    import time

    for req in requests:
        req.max_new_tokens = max_new_tokens
        sched.submit(req)

    step = 0
    while sched.has_work:
        progressed = run_admission(eng, sched, preemption=preemption)
        if not sched.running:
            if progressed:
                continue     # whole batch retired at the admission seed
                             # (max_new_tokens == 1): admit the next one
            break                      # nothing admissible: pool too small
        t0 = time.perf_counter()
        tokens = eng.step()
        if step_times_us is not None:
            step_times_us.append((time.perf_counter() - t0) * 1e6)
        step += 1
        finished = sched.note_decode_step(tokens)
        if finished:
            # demotion keys must be captured before sched.complete drops
            # the running entries (prefix cache on only)
            kv_toks = {l: sched.kv_token_prefix(l) for l in finished} \
                if eng.cache is not None else None
            eng.release(finished, kv_tokens=kv_toks)
            sched.complete(finished)
        if verbose and step % log_every == 0:
            print(f"step {step}: done={len(sched.finished)}/{len(requests)} "
                  f"waiting={len(sched.waiting)} "
                  f"live_pages={eng.live_pages} "
                  f"peak={int(eng.state.paged.alloc.peak_used[eng.tenants.kv.size_class])}")
    if sched.waiting:
        print(f"WARNING: admission starved — {len(sched.waiting)} request(s) "
              f"not served (page budget {eng.free_pages} free - "
              f"{sched.scfg.page_reserve} reserve cannot fit the next one)")
    return step


def serve_loadgen(cfg, kvcfg, params, scfg, args) -> None:
    """Open-loop path of the launcher (DESIGN.md §14): seeded arrivals,
    virtual-time submission, tail-latency report, optional trace record."""
    from ..loadgen import LoadgenSpec, build_workload, run_open_loop
    from ..loadgen.trace import record_service, save_trace

    me = MultiEngine(cfg, kvcfg, params, n_engines=args.engines,
                     dtype=jnp.float32, sched_cfg=scfg,
                     quantum=args.quantum, preemption=args.preemption,
                     router=args.router, alloc_backend=args.alloc_backend,
                     alloc_policy=args.alloc_policy,
                     prefix_cache=args.prefix_cache == "on",
                     eviction=args.eviction,
                     cache_pages=args.cache_pages,
                     prefix_alias=args.prefix_alias)
    rec = record_service(me.service) if args.record_trace else None
    spec = LoadgenSpec(n_requests=args.requests, arrival=args.loadgen,
                       rate=args.rate, priority_frac=args.priority_frac,
                       shared_prefix_frac=args.shared_prefix_frac,
                       output_cap=args.max_new_tokens, seed=args.seed)
    timed = build_workload(spec, cfg.vocab_size)
    report = run_open_loop(me, timed, max_windows=args.max_windows,
                           verbose=True)
    print(f"open-loop {spec.arrival} rate={spec.rate}/step seed={spec.seed}: "
          f"completed={report.completed} failed={report.failed} "
          f"stranded={report.stranded} in {report.windows} windows "
          f"({report.wall_s:.1f}s)")
    print(f"  TTFT p50={report.p50_ttft_us / 1e3:.1f}ms "
          f"p90={report.p90_ttft_us / 1e3:.1f}ms "
          f"p99={report.p99_ttft_us / 1e3:.1f}ms "
          f"(virtual: p50={report.p50_ttft_steps:.1f} "
          f"p99={report.p99_ttft_steps:.1f} steps)")
    print(f"  per-token p50={report.p50_tpot_us / 1e3:.1f}ms "
          f"p99={report.p99_tpot_us / 1e3:.1f}ms | "
          f"queue depth mean={report.queue_depth_mean:.1f} "
          f"max={report.queue_depth_max}")
    for i, e in enumerate(me.engines):
        kv_frag = next((rep for name, rep in e.fragmentation_report().items()
                        if name.endswith("kv_pages")), None)
        if kv_frag is None:
            continue
        print(f"  e{i}: mean_run_len={e.stats.mean_run_len:.2f} "
              f"external_frag={kv_frag['external_frag']:.2f} "
              f"largest_free_run={kv_frag['largest_free_run']} "
              f"splits={kv_frag['split_count']} merges={kv_frag['merge_count']}")
    if rec is not None:
        me.service.recorder = None
        trace = rec.finish(
            complete=sum(e.stats.decode_bursts for e in me.engines) == 0)
        save_trace(trace, args.record_trace)
        print(f"  trace: {trace.bursts} bursts ({trace.live_bursts} live, "
              f"{trace.ops} ops) {trace.windows} windows -> "
              f"{args.record_trace} complete={trace.header['complete']} "
              f"(replay: python -m repro.launch.replay {args.record_trace})")


def serve_multi(cfg, kvcfg, params, scfg, requests, args) -> None:
    """Multi-engine sharded serving path of the launcher (DESIGN.md §10)."""
    me = MultiEngine(cfg, kvcfg, params, n_engines=args.engines,
                     dtype=jnp.float32, sched_cfg=scfg,
                     quantum=args.quantum, preemption=args.preemption,
                     router=args.router, alloc_backend=args.alloc_backend,
                     alloc_policy=args.alloc_policy,
                     prefix_cache=args.prefix_cache == "on",
                     eviction=args.eviction,
                     cache_pages=args.cache_pages,
                     prefix_alias=args.prefix_alias)
    windows = me.serve(requests, max_new_tokens=args.max_new_tokens,
                       verbose=True)
    st = me.stats
    failed = me.failed
    if failed:
        print(f"FAILED: {len(failed)} request(s) rejected by the allocator")
    print(f"served {len(me.finished)} requests across {args.engines} engines "
          f"in {windows} windows ({st.decode_steps} engine-steps) | "
          f"alloc_backend={me.alloc_backend} alloc_policy={me.alloc_policy} "
          f"router={args.router} quantum={args.quantum} "
          f"preemption={args.preemption} | "
          f"window_commits={st.window_commits} "
          f"cross_engine_burst_occupancy={st.cross_engine_burst_occupancy:.2f} "
          f"preemptions={st.preemptions} | "
          # one tenant-agnostic decode executable for all shards (§13):
          # decode_compiles stays 1 however many engines are deployed
          f"decode_compiles={st.decode_compiles} "
          f"decode_compile_ms={st.decode_compile_us / 1e3:.0f}")
    for i, eng in enumerate(me.engines):
        s = eng.stats
        cache = (f" cache_hit_rate={s.cache_hit_rate:.2f} "
                 f"prefill_tokens_saved={s.prefill_tokens_saved} "
                 f"aliased_pages={s.aliased_pages} "
                 f"hit_copy_bytes={s.cache_hit_copy_bytes}"
                 if eng.cache is not None else "")
        print(f"  e{i}: admitted={s.admitted} completed={s.completed} "
              f"decode_steps={s.decode_steps} "
              f"stash_hit_rate={s.stash_hit_rate:.2f} "
              f"decode_bursts/1k={s.hmq_bursts_per_1k_decode_steps:.0f}"
              f"{cache}")
    print("cross-engine tenant rollup (one shared AllocService):")
    for name, d in me.tenant_rollup().items():
        print(f"  {name}: engines={d['engines']} used={d['used']}/{d['quota']} "
              f"peak={d['peak_used']} allocs={d['alloc_count']} "
              f"frees={d['free_count']} fails={d['fail_count']}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--engines", type=int, default=1,
                    help="engine shards on ONE shared AllocService; >1 "
                         "drives the multi-engine async loop (DESIGN.md §10)")
    ap.add_argument("--quantum", type=int, default=4,
                    help="burst-window length in decode steps (multi-engine "
                         "loop): deferred allocator traffic from every shard "
                         "merges into one commit per window")
    ap.add_argument("--preemption", action="store_true",
                    help="evict the lowest-priority running lane when a "
                         "higher-priority request cannot be admitted")
    ap.add_argument("--router", default="round_robin",
                    choices=list(ROUTER_POLICIES),
                    help="multi-engine request routing policy")
    ap.add_argument("--priority-every", type=int, default=0,
                    help="mark every k-th synthetic request priority 1 "
                         "(exercises --preemption)")
    ap.add_argument("--stash-size", type=int, default=None,
                    help="per-lane page-stash size (0 disables the front "
                         "tier; default: autotuned from boundary cadence)")
    ap.add_argument("--alloc-backend", default=None,
                    choices=list(ALLOC_BACKENDS),
                    help="support-core step implementation (default: "
                         "REPRO_ALLOC_BACKEND env or 'jnp'; 'kernel' is the "
                         "fused Pallas burst, TPU only; 'kernel-interpret' "
                         "runs it through the Pallas interpreter)")
    ap.add_argument("--alloc-policy", default=None,
                    choices=list(ALLOC_POLICIES),
                    help="central-allocator policy (default: "
                         "REPRO_ALLOC_POLICY env or 'freelist'; 'bitmap' is "
                         "the address-ordered first-fit AllocatorPolicy — "
                         "DESIGN.md §9)")
    ap.add_argument("--prefix-cache", default="off", choices=["on", "off"],
                    help="keep completed requests' full KV pages cached by "
                         "token prefix and skip their prefill on a hit "
                         "(DESIGN.md §11)")
    ap.add_argument("--eviction", default=None,
                    choices=list(EVICTION_POLICIES),
                    help="prefix-cache eviction policy (default: "
                         "REPRO_KV_EVICTION env or 'lru')")
    ap.add_argument("--cache-pages", type=int, default=None,
                    help="prefix-cache page budget (default: half the KV "
                         "pool; charged against the kv tenant quota)")
    ap.add_argument("--prefix-alias", default=None, choices=["copy", "alias"],
                    help="prefix-cache hit admission mode (default: "
                         "REPRO_PREFIX_ALIAS env or 'copy'): 'copy' gathers "
                         "cached K/V into fresh lane pages, 'alias' splices "
                         "the cache pages into the lane's block table with a "
                         "refcount bump — zero copy (DESIGN.md §12)")
    ap.add_argument("--loadgen", default="off",
                    choices=["off", "poisson", "bursty", "diurnal"],
                    help="open-loop arrival process (DESIGN.md §14); "
                         "anything but 'off' drives the multi-engine loop "
                         "by virtual arrival time and reports TTFT "
                         "percentiles instead of closed-loop throughput")
    ap.add_argument("--rate", type=float, default=0.15,
                    help="open-loop mean arrivals per decode step")
    ap.add_argument("--priority-frac", type=float, default=0.0,
                    help="open-loop fraction of requests at priority 1")
    ap.add_argument("--shared-prefix-frac", type=float, default=0.0,
                    help="open-loop fraction of prompts opening with one "
                         "common prefix (exercises --prefix-cache)")
    ap.add_argument("--record-trace", default=None, metavar="FILE",
                    help="serialize the allocator-op stream of the "
                         "open-loop run to FILE for model-free replay")
    ap.add_argument("--max-windows", type=int, default=None,
                    help="open-loop window budget (smoke-run bound)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    rng = np.random.RandomState(args.seed)
    kvcfg = make_paged_config(cfg, seq_len=256, lanes=args.lanes,
                              page_size=args.page_size, dtype=jnp.float32,
                              stash_size=args.stash_size)
    params = init_params(cfg, dtype=jnp.float32)
    scfg = make_scheduler_config(cfg, kvcfg, max_prompt_len=128)
    if args.loadgen != "off":
        serve_loadgen(cfg, kvcfg, params, scfg, args)
        return

    requests = synth_requests(cfg, args.requests, rng,
                              priority_every=args.priority_every)

    if args.engines > 1:
        serve_multi(cfg, kvcfg, params, scfg, requests, args)
        return

    eng = ServingEngine(cfg, kvcfg, params, dtype=jnp.float32, sched_cfg=scfg,
                        alloc_backend=args.alloc_backend,
                        alloc_policy=args.alloc_policy,
                        prefix_cache=args.prefix_cache == "on",
                        eviction=args.eviction,
                        cache_pages=args.cache_pages,
                        prefix_alias=args.prefix_alias)
    sched = Scheduler(scfg)

    steps = serve_loop(eng, sched, requests, args.max_new_tokens,
                       preemption=args.preemption)

    a = eng.state.paged.alloc
    s = eng.stats
    kv_cls = eng.tenants.kv.size_class
    if sched.failed:
        print(f"FAILED: {len(sched.failed)} request(s) rejected by the allocator")
    print(f"served {len(sched.finished)} requests in {steps} decode steps | "
          f"alloc_backend={eng.alloc_backend} alloc_policy={eng.alloc_policy} "
          f"stash={kvcfg.stash_size}/{kvcfg.stash_watermark}"
          f"/{kvcfg.stash_refill} | "
          f"allocs={int(a.alloc_count[kv_cls])} frees={int(a.free_count[kv_cls])} "
          f"fails={int(a.fail_count[kv_cls])} peak_pages={int(a.peak_used[kv_cls])} "
          f"live={int(live_pages(eng.state.paged, eng.tenants))} | "
          f"admit_bursts={s.hmq_admit_bursts} "
          f"({s.hmq_admit_bursts / max(s.admitted, 1):.2f}/seq) "
          f"prefill_compiles={s.prefill_compiles} "
          f"decode_compiles={s.decode_compiles} "
          f"decode_compile_ms={s.decode_compile_us / 1e3:.0f} "
          f"preemptions={s.preemptions} | "
          f"stash_hit_rate={s.stash_hit_rate:.2f} "
          f"decode_bursts/1k={s.hmq_bursts_per_1k_decode_steps:.0f} "
          f"stash_depth_hist={s.stash_depth_hist}")
    if eng.cache is not None:
        print(f"prefix_cache: hit_rate={s.cache_hit_rate:.2f} "
              f"prefill_tokens_saved={s.prefill_tokens_saved} "
              f"pages={s.cache_pages}/{eng.cache.budget} "
              f"inserts={s.cache_inserts} evictions={s.cache_evictions} "
              f"policy={eng.cache.policy.name} mode={eng.prefix_alias} "
              f"aliased_pages={s.aliased_pages} "
              f"hit_copy_bytes={s.cache_hit_copy_bytes} "
              f"hit_admit_us={s.hit_admit_us:.0f}")
    # contiguity + fragmentation: what the policy's placement actually did
    # to the address space (DESIGN.md §15)
    frag = eng.fragmentation_report()
    kv_frag = next((rep for name, rep in frag.items()
                    if name.endswith("kv_pages")), None)
    if kv_frag is not None:
        print(f"contiguity: mean_run_len={s.mean_run_len:.2f} "
              f"extents={s.contiguous_extents} "
              f"external_frag={kv_frag['external_frag']:.2f} "
              f"largest_free_run={kv_frag['largest_free_run']} "
              f"splits={kv_frag['split_count']} "
              f"merges={kv_frag['merge_count']} "
              f"compactions={s.compactions} "
              f"compaction_moves={s.compaction_moves}")
    # per-tenant view: the multi-tenant support-core claim, measured
    print(f"burst_occupancy={s.burst_occupancy:.2f} | tenants:")
    for name, rep in eng.tenant_report().items():
        acc = s.tenants.get(name, {})
        print(f"  {name}: used={rep['used']}/{rep['quota']} "
              f"peak={rep['peak_used']} allocs={rep['alloc_count']} "
              f"frees={rep['free_count']} fails={rep['fail_count']} "
              f"(burst mallocs={acc.get('mallocs', 0)} "
              f"failed={acc.get('failed', 0)})")


if __name__ == "__main__":
    main()
