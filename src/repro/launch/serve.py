"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Continuous-batching serving demo on the SpeedMalloc paged KV cache:
Poisson request arrivals with Pareto-ish lengths (the paper's Larson-style
server-client pattern), admission through support-core burst allocation,
per-step HMQ batches during decode, page recycling for SWA archs, release
on completion.  Prints allocator telemetry (live pages, peak, HMQ stats).
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from ..configs.base import ARCH_IDS, smoke_config
from ..core.paged_kv import live_pages
from ..models import init_params, make_paged_config
from ..serve.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--lanes", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    rng = np.random.RandomState(args.seed)
    kvcfg = make_paged_config(cfg, seq_len=256, lanes=args.lanes,
                              page_size=args.page_size, dtype=jnp.float32)
    params = init_params(cfg, dtype=jnp.float32)
    eng = ServingEngine(cfg, kvcfg, params, dtype=jnp.float32)

    pending = list(range(args.requests))
    lane_req: dict[int, int] = {}
    remaining: dict[int, int] = {}
    done = 0
    step = 0
    while done < args.requests:
        # admit into free lanes (continuous batching)
        for lane in range(args.lanes):
            if lane not in lane_req and pending:
                rid = pending.pop(0)
                plen = int(rng.pareto(2.0) * 20) % 96 + 8
                toks = rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
                frames = (rng.randn(cfg.encoder_seq_len, cfg.d_model).astype(np.float32)
                          if cfg.family == "audio" else None)
                patches = (rng.randn(4, cfg.d_model).astype(np.float32)
                           if cfg.family == "vlm" else None)
                eng.admit(lane, toks, frames=frames, patches=patches)
                lane_req[lane] = rid
                remaining[lane] = args.max_new_tokens
        eng.step()
        step += 1
        finished = []
        for lane in list(lane_req):
            remaining[lane] -= 1
            if remaining[lane] <= 0:
                finished.append(lane)
        if finished:
            eng.release(finished)
            for lane in finished:
                done += 1
                del lane_req[lane], remaining[lane]
        if step % 8 == 0:
            print(f"step {step}: done={done}/{args.requests} "
                  f"live_pages={eng.live_pages} "
                  f"peak={int(eng.state.paged.alloc.peak_used[0])}")
    a = eng.state.paged.alloc
    print(f"served {done} requests in {step} decode steps | "
          f"allocs={int(a.alloc_count[0])} frees={int(a.free_count[0])} "
          f"fails={int(a.fail_count[0])} peak_pages={int(a.peak_used[0])} "
          f"live={int(live_pages(eng.state.paged))}")


if __name__ == "__main__":
    main()
