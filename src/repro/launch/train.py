"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (small-scale, CPU-friendly) training loop with the full
substrate: deterministic sharded data pipeline, AdamW, grad accumulation,
async checkpointing, watchdog, restart-on-failure.  On a TPU pod the same
driver runs under the production mesh (``--mesh pod``) with the sharding
rules from ``repro.distributed.sharding``.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, get_config, smoke_config
from ..train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        grad_accum=args.grad_accum,
        batch_size=args.batch_size,
        seq_len=args.seq_len,
    )
    dtype = jnp.float32 if args.smoke else jnp.bfloat16
    report = Trainer(cfg, tcfg, dtype=dtype).run()
    print(f"done: steps={report.steps_run} final_loss={report.final_loss:.4f} "
          f"stragglers={report.straggler_steps} restarts={report.restarts}")


if __name__ == "__main__":
    main()
